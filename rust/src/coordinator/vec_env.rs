//! Vectorized environment driver: N actor threads stepping independent
//! env instances with a shared policy snapshot, feeding the replay
//! service — the ingest side of the serving example and the throughput
//! benches.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use super::ReplaySink;
use crate::envs;
use crate::replay::Experience;
use crate::util::Rng;

/// Runs `n_envs` actor threads with random policies (exploration phase) —
/// the policy-driven path lives in the agent; this driver exists to
/// exercise ingest concurrency and backpressure.
pub struct VectorEnvDriver {
    stop: Arc<AtomicBool>,
    steps: Arc<AtomicU64>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl VectorEnvDriver {
    /// Spawn the actors. Each steps its own env and pushes every
    /// transition to `service` (either a [`super::ServiceHandle`] or a
    /// [`super::ShardedHandle`]). Actors exit when the service stops
    /// accepting pushes.
    pub fn spawn<S: ReplaySink>(
        env_name: &str,
        n_envs: usize,
        service: S,
        seed: u64,
    ) -> VectorEnvDriver {
        let stop = Arc::new(AtomicBool::new(false));
        let steps = Arc::new(AtomicU64::new(0));
        let mut threads = Vec::with_capacity(n_envs);
        for i in 0..n_envs {
            let name = env_name.to_string();
            let svc = service.clone();
            let stop_flag = stop.clone();
            let counter = steps.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("actor-{i}"))
                    .spawn(move || {
                        let mut env = envs::make(&name)
                            .unwrap_or_else(|| panic!("unknown env {name}"));
                        let mut rng =
                            Rng::new(seed ^ (i as u64).wrapping_mul(0xA5A5_A5A5));
                        let mut obs = env.reset(&mut rng);
                        while !stop_flag.load(Ordering::Relaxed) {
                            let action = rng.below(env.n_actions());
                            let step = env.step(action, &mut rng);
                            let accepted = svc.push_experience(Experience {
                                obs: obs.clone(),
                                action: action as u32,
                                reward: step.reward,
                                next_obs: step.obs.clone(),
                                done: step.terminated,
                            });
                            if !accepted {
                                break; // service stopped — stop producing
                            }
                            counter.fetch_add(1, Ordering::Relaxed);
                            obs = if step.done() {
                                env.reset(&mut rng)
                            } else {
                                step.obs
                            };
                        }
                    })
                    .expect("spawn actor"),
            );
        }
        VectorEnvDriver { stop, steps, threads }
    }

    /// Total env steps pushed so far.
    pub fn steps(&self) -> u64 {
        self.steps.load(Ordering::Relaxed)
    }

    /// Signal and join all actors.
    pub fn stop(mut self) -> u64 {
        self.stop.store(true, Ordering::Relaxed);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        self.steps.load(Ordering::Relaxed)
    }
}

impl Drop for VectorEnvDriver {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ReplayService;
    use crate::replay::ReplayKind;

    #[test]
    fn actors_fill_the_memory() {
        let svc = ReplayService::spawn(
            crate::replay::make(ReplayKind::Uniform, 10_000),
            1024,
            0,
        );
        let driver = VectorEnvDriver::spawn("cartpole", 4, svc.handle(), 42);
        // run until we've ingested a healthy number of steps
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while driver.steps() < 2000 && std::time::Instant::now() < deadline {
            std::thread::yield_now();
        }
        let total = driver.stop();
        assert!(total >= 2000, "only {total} steps ingested");
        let mem = svc.stop();
        assert!(mem.len() > 1000);
    }
}
