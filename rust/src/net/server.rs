//! The standalone replay-tier server: wraps an in-process replay
//! service (single-owner or sharded) behind the wire protocol so N
//! learner clients and M actor fleets on other processes/hosts share
//! one tier.
//!
//! Threading model: one nonblocking accept loop plus **one handler
//! thread per connection**. A handler reads frames sequentially,
//! feeds the existing service command queue through a [`TierPort`],
//! and writes replies back on the same socket — so each connection is
//! a FIFO command stream exactly like an in-process handle clone, and
//! a single remote learner reproduces the in-process training stream
//! bit-for-bit (pinned by `batch_equivalence`).
//!
//! Tenancy: every client gets its own [`ClientStats`] (pushes /
//! samples / priority updates / frame errors) and its own private
//! [`ReplyPool`], so the zero-copy gathered path survives the process
//! boundary per client and one tenant can never starve another's
//! buffers. Priority updates arrive tagged with the client id the
//! handshake assigned (the frame header carries it).
//!
//! Failure isolation: a malformed, oversized, or unknown frame closes
//! **only that client's connection** with a counted `frame_errors` —
//! never the server; a client that disconnects mid-gather has its
//! pending reply drained and the lent pool buffer recycled
//! ([`ReplyPool::put`] / [`ReplyPool::note_lost`] keep the pool
//! accounting identity intact); a stalled client that stops reading
//! fails its own writes after `write_timeout` and is dropped while
//! every other client keeps training.
//!
//! Snapshots: learner clients publish [`PolicySnapshot`]s with
//! `SnapshotPut`; the server installs them newest-epoch-wins into a
//! hub and relays the current snapshot to actor connections
//! piggybacked on their frame cadence (each received actor frame may
//! carry one snapshot push back), so remote actors stay epoch-fresh
//! without a dedicated relay thread per client.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::wire::{
    self, read_frame_opt, write_frame, Listener, Opcode, Role, Stream,
};
use crate::coordinator::{
    PendingGather, PolicySnapshot, ReplyPool, ServiceHandle, ShardedHandle,
};
use crate::replay::ExperienceBatch;
use crate::util::error::Result;
use crate::util::json::{obj, Json};

/// What the net server needs from the replay tier it fronts: batch
/// ingest, gathered sampling against a caller-owned reply pool, and
/// priority feedback. Implemented by both in-process handle shapes.
pub trait TierPort: Clone + Send + 'static {
    /// Store a batch; `false` means the service has stopped.
    fn push_batch(&self, batch: ExperienceBatch) -> bool;
    /// Issue a gather whose reply buffer comes from (and whose recovery
    /// settles into) `pool` — the server passes each client's private
    /// pool here.
    fn request_gathered_into(&self, batch: usize, pool: &ReplyPool)
        -> PendingGather;
    /// Route TD errors back; `false` means (part of) the update dropped.
    fn update_priorities(&self, indices: Vec<usize>, td: Vec<f32>) -> bool;
}

impl TierPort for ServiceHandle {
    fn push_batch(&self, batch: ExperienceBatch) -> bool {
        ServiceHandle::push_batch(self, batch)
    }

    fn request_gathered_into(
        &self,
        batch: usize,
        pool: &ReplyPool,
    ) -> PendingGather {
        ServiceHandle::request_gathered_into(self, batch, pool)
    }

    fn update_priorities(&self, indices: Vec<usize>, td: Vec<f32>) -> bool {
        ServiceHandle::update_priorities(self, indices, td)
    }
}

impl TierPort for ShardedHandle {
    fn push_batch(&self, batch: ExperienceBatch) -> bool {
        ShardedHandle::push_batch(self, batch)
    }

    fn request_gathered_into(
        &self,
        batch: usize,
        pool: &ReplyPool,
    ) -> PendingGather {
        ShardedHandle::request_gathered_into(self, batch, pool)
    }

    fn update_priorities(&self, indices: Vec<usize>, td: Vec<f32>) -> bool {
        ShardedHandle::update_priorities(self, indices, td)
    }
}

/// Per-client counters, registered at handshake and kept after the
/// client disconnects (the tier's tenancy ledger).
pub struct ClientStats {
    /// Handshake-assigned id (also the `client` field of every reply
    /// frame sent to this client).
    pub id: u32,
    pub role: Role,
    /// Transitions (batch rows) accepted from this client.
    pub pushes: AtomicU64,
    /// Gathered batches served to this client.
    pub samples: AtomicU64,
    /// Priority-update messages accepted from this client.
    pub priority_updates: AtomicU64,
    /// Malformed / oversized / out-of-protocol frames; any of these
    /// closes the connection.
    pub frame_errors: AtomicU64,
    /// Cleared when the connection closes (for any reason).
    pub connected: AtomicBool,
    /// This client's private gathered-reply pool.
    pool: ReplyPool,
}

impl ClientStats {
    fn new(id: u32, role: Role, pool: ReplyPool) -> ClientStats {
        ClientStats {
            id,
            role,
            pushes: AtomicU64::new(0),
            samples: AtomicU64::new(0),
            priority_updates: AtomicU64::new(0),
            frame_errors: AtomicU64::new(0),
            connected: AtomicBool::new(true),
            pool,
        }
    }

    /// The client's private reply pool (accounting assertions in tests;
    /// the quiescent identity `hits + misses == recycled + dropped`
    /// holds per client because each handler settles every request it
    /// issued before moving on).
    pub fn reply_pool(&self) -> &ReplyPool {
        &self.pool
    }

    pub fn to_json(&self) -> Json {
        let n = |c: &AtomicU64| Json::Num(c.load(Ordering::Relaxed) as f64);
        obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("role", Json::Str(self.role.as_str().to_string())),
            ("pushes", n(&self.pushes)),
            ("samples", n(&self.samples)),
            ("priority_updates", n(&self.priority_updates)),
            ("frame_errors", n(&self.frame_errors)),
            (
                "connected",
                Json::Bool(self.connected.load(Ordering::Relaxed)),
            ),
            ("pool", self.pool.stats().to_json()),
        ])
    }
}

/// The server's snapshot relay hub. Learner clients race `SnapshotPut`s
/// into it; the **highest epoch wins** (multi-learner publishes merge
/// monotonically). Stored as `Option` because a freshly started tier
/// knows neither params nor dims until the first learner publishes.
struct SnapshotHub {
    slot: Mutex<Option<Arc<PolicySnapshot>>>,
    /// `epoch + 1` of the held snapshot; 0 = none yet. Monotonic.
    marker: AtomicU64,
}

impl SnapshotHub {
    fn install(&self, snap: PolicySnapshot) -> bool {
        let mut slot = self.slot.lock().expect("snapshot hub poisoned");
        let m = snap.epoch().saturating_add(1);
        if m <= self.marker.load(Ordering::Acquire) {
            return false;
        }
        *slot = Some(Arc::new(snap));
        self.marker.store(m, Ordering::Release);
        true
    }

    fn load(&self) -> Option<Arc<PolicySnapshot>> {
        self.slot.lock().expect("snapshot hub poisoned").clone()
    }

    fn marker(&self) -> u64 {
        self.marker.load(Ordering::Acquire)
    }
}

/// Tuning for [`NetServer::spawn_with`].
#[derive(Debug, Clone)]
pub struct NetServerOptions {
    /// Idle buffers retained in each client's private reply pool.
    pub reply_pool: usize,
    /// Bound on a blocking reply write: a client that stops reading
    /// (stalled peer) fails its own connection after this instead of
    /// wedging its handler forever.
    pub write_timeout: Duration,
}

impl Default for NetServerOptions {
    fn default() -> NetServerOptions {
        NetServerOptions {
            reply_pool: crate::coordinator::service::DEFAULT_REPLY_POOL,
            write_timeout: Duration::from_secs(5),
        }
    }
}

struct Shared {
    clients: Mutex<Vec<Arc<ClientStats>>>,
    /// Shutdown handles for every accepted connection (stop path).
    conns: Mutex<Vec<Stream>>,
    handlers: Mutex<Vec<JoinHandle<()>>>,
    hub: SnapshotHub,
    next_id: AtomicU32,
    /// Connections dropped before a valid `Hello` completed.
    handshake_errors: AtomicU64,
    stop: AtomicBool,
    opts: NetServerOptions,
}

/// The running wire-protocol replay tier (owns the accept loop and all
/// connection handler threads).
pub struct NetServer {
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    addr: String,
}

impl NetServer {
    /// Serve `port` on `listener` with default options.
    pub fn spawn<P: TierPort>(port: P, listener: Listener) -> Result<NetServer> {
        Self::spawn_with(port, listener, NetServerOptions::default())
    }

    /// Serve `port` on `listener`; one handler thread per accepted
    /// connection, commands forwarded to the wrapped service's queue.
    pub fn spawn_with<P: TierPort>(
        port: P,
        listener: Listener,
        opts: NetServerOptions,
    ) -> Result<NetServer> {
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            clients: Mutex::new(Vec::new()),
            conns: Mutex::new(Vec::new()),
            handlers: Mutex::new(Vec::new()),
            hub: SnapshotHub { slot: Mutex::new(None), marker: AtomicU64::new(0) },
            next_id: AtomicU32::new(0),
            handshake_errors: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            opts,
        });
        let accept_shared = Arc::clone(&shared);
        let accept = std::thread::Builder::new()
            .name("replay-net-accept".into())
            .spawn(move || accept_loop(port, listener, accept_shared))
            .map_err(|e| crate::err!("spawn accept loop: {e}"))?;
        Ok(NetServer { shared, accept: Some(accept), addr })
    }

    /// The bound address in `Stream::connect` syntax (resolves TCP
    /// port 0 to the actual port).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Per-client stats, in handshake order (disconnected clients stay
    /// listed — the ledger of what each tenant did).
    pub fn clients(&self) -> Vec<Arc<ClientStats>> {
        self.shared.clients.lock().expect("client list poisoned").clone()
    }

    /// Connections dropped before a valid handshake.
    pub fn handshake_errors(&self) -> u64 {
        self.shared.handshake_errors.load(Ordering::Relaxed)
    }

    /// Epoch of the snapshot currently held by the relay hub.
    pub fn snapshot_epoch(&self) -> Option<u64> {
        self.shared.hub.marker().checked_sub(1)
    }

    /// The tenancy ledger as JSON (for `replay-serve` reports).
    pub fn clients_json(&self) -> Json {
        Json::Arr(self.clients().iter().map(|c| c.to_json()).collect())
    }

    /// Stop accepting, shut every live connection down, and join all
    /// handler threads. The wrapped replay service is untouched — the
    /// caller still owns it.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        for conn in self.shared.conns.lock().expect("conn list poisoned").iter() {
            conn.shutdown();
        }
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        let handlers: Vec<_> = {
            let mut h =
                self.shared.handlers.lock().expect("handler list poisoned");
            h.drain(..).collect()
        };
        for h in handlers {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop<P: TierPort>(port: P, listener: Listener, shared: Arc<Shared>) {
    while !shared.stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok(stream) => {
                // keep a shutdown handle so stop() can unblock the
                // handler's reads
                if let Ok(clone) = stream.try_clone() {
                    shared.conns.lock().expect("conn list poisoned").push(clone);
                }
                let port = port.clone();
                let conn_shared = Arc::clone(&shared);
                let h = std::thread::Builder::new()
                    .name("replay-net-conn".into())
                    .spawn(move || handle_conn(port, stream, conn_shared));
                if let Ok(h) = h {
                    shared.handlers.lock().expect("handler list poisoned").push(h);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => {
                if shared.stop.load(Ordering::Relaxed) {
                    break;
                }
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

/// Send the hub snapshot if it moved past `sent_marker` (actor relay).
/// Returns `false` when the write failed (connection is done).
fn relay_snapshot(
    stream: &mut Stream,
    hub: &SnapshotHub,
    client: u32,
    sent_marker: &mut u64,
    scratch: &mut Vec<u8>,
) -> bool {
    let m = hub.marker();
    if m <= *sent_marker {
        return true;
    }
    let Some(snap) = hub.load() else { return true };
    wire::encode_snapshot(scratch, &snap);
    if write_frame(stream, Opcode::Snapshot, client, scratch).is_err() {
        return false;
    }
    *sent_marker = m;
    true
}

fn handle_conn<P: TierPort>(port: P, mut stream: Stream, shared: Arc<Shared>) {
    let _ = stream.set_write_timeout(Some(shared.opts.write_timeout));
    let mut payload = Vec::new();
    let mut scratch = Vec::new();

    // handshake: exactly one valid Hello, or the connection is dropped
    let role = match read_frame_opt(&mut stream, &mut payload) {
        Ok(Some(h)) if h.opcode == Opcode::Hello => {
            match wire::decode_hello(&payload) {
                Ok(role) => role,
                Err(_) => {
                    shared.handshake_errors.fetch_add(1, Ordering::Relaxed);
                    return;
                }
            }
        }
        _ => {
            shared.handshake_errors.fetch_add(1, Ordering::Relaxed);
            return;
        }
    };
    let id = shared.next_id.fetch_add(1, Ordering::Relaxed) + 1;
    let stats = Arc::new(ClientStats::new(
        id,
        role,
        ReplyPool::new(shared.opts.reply_pool),
    ));
    shared.clients.lock().expect("client list poisoned").push(Arc::clone(&stats));
    wire::encode_hello_ack(&mut scratch, shared.hub.marker());
    if write_frame(&mut stream, Opcode::HelloAck, id, &scratch).is_err() {
        stats.connected.store(false, Ordering::Relaxed);
        return;
    }

    // actors get the current snapshot immediately, then via piggyback
    let mut sent_marker = 0u64;
    if role == Role::Actor
        && !relay_snapshot(&mut stream, &shared.hub, id, &mut sent_marker, &mut scratch)
    {
        stats.connected.store(false, Ordering::Relaxed);
        return;
    }

    loop {
        let header = match read_frame_opt(&mut stream, &mut payload) {
            Ok(Some(h)) => h,
            // clean close at a frame boundary: not a frame error
            Ok(None) => break,
            Err(_) => {
                // malformed / oversized / unknown frame, or a read cut
                // mid-frame: close THIS connection only
                stats.frame_errors.fetch_add(1, Ordering::Relaxed);
                break;
            }
        };
        let ok = match header.opcode {
            Opcode::PushBatch => match wire::decode_push_batch(&payload) {
                Ok(b) => {
                    let rows = b.len() as u64;
                    if port.push_batch(b) {
                        stats.pushes.fetch_add(rows, Ordering::Relaxed);
                    }
                    true
                }
                Err(_) => false,
            },
            Opcode::SampleGathered => {
                match wire::decode_sample_gathered(&payload) {
                    Ok(batch) => {
                        let pending = port
                            .request_gathered_into(batch as usize, &stats.pool);
                        match pending.wait() {
                            Ok(g) => {
                                wire::encode_gathered(&mut scratch, &g);
                                let sent = write_frame(
                                    &mut stream,
                                    Opcode::GatheredOk,
                                    id,
                                    &scratch,
                                )
                                .is_ok();
                                // the reply buffer goes back to this
                                // client's pool either way — a client
                                // that vanished mid-gather must not
                                // leak the lent buffer
                                stats.pool.put(g);
                                if sent {
                                    stats
                                        .samples
                                        .fetch_add(1, Ordering::Relaxed);
                                }
                                sent
                            }
                            Err(e) => {
                                // the wait already settled the pool
                                // accounting (note_lost on timeout /
                                // worker death)
                                wire::encode_gathered_err(
                                    &mut scratch,
                                    &e.to_string(),
                                );
                                write_frame(
                                    &mut stream,
                                    Opcode::GatheredErr,
                                    id,
                                    &scratch,
                                )
                                .is_ok()
                            }
                        }
                    }
                    Err(_) => false,
                }
            }
            Opcode::UpdatePriorities => {
                match wire::decode_update_priorities(&payload) {
                    Ok((indices, td)) => {
                        if port.update_priorities(indices, td) {
                            stats
                                .priority_updates
                                .fetch_add(1, Ordering::Relaxed);
                        }
                        true
                    }
                    Err(_) => false,
                }
            }
            Opcode::SnapshotPut => match wire::decode_snapshot(&payload) {
                Ok(snap) => {
                    shared.hub.install(snap);
                    true
                }
                Err(_) => false,
            },
            Opcode::SnapshotGet => match wire::decode_snapshot_get(&payload) {
                Ok(have) => {
                    if shared.hub.marker() > have {
                        if let Some(snap) = shared.hub.load() {
                            wire::encode_snapshot(&mut scratch, &snap);
                            sent_marker = shared.hub.marker();
                            write_frame(
                                &mut stream,
                                Opcode::Snapshot,
                                id,
                                &scratch,
                            )
                            .is_ok()
                        } else {
                            write_frame(&mut stream, Opcode::SnapshotNone, id, &[])
                                .is_ok()
                        }
                    } else {
                        write_frame(&mut stream, Opcode::SnapshotNone, id, &[])
                            .is_ok()
                    }
                }
                Err(_) => false,
            },
            // server-bound connections must never carry reply opcodes
            Opcode::Hello
            | Opcode::HelloAck
            | Opcode::GatheredOk
            | Opcode::GatheredErr
            | Opcode::Snapshot
            | Opcode::SnapshotNone => false,
        };
        if !ok {
            stats.frame_errors.fetch_add(1, Ordering::Relaxed);
            break;
        }
        // epoch-freshness relay: piggyback at the actor's frame cadence
        if role == Role::Actor
            && !relay_snapshot(
                &mut stream,
                &shared.hub,
                id,
                &mut sent_marker,
                &mut scratch,
            )
        {
            break;
        }
    }
    stats.connected.store(false, Ordering::Relaxed);
    stream.shutdown();
}
