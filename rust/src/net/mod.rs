//! The remote replay tier: a zero-dependency wire protocol ([`wire`]),
//! a standalone server that fronts an in-process replay service for
//! many clients ([`server`]), and a client handle that slots into the
//! existing actor/learner machinery unchanged ([`client`]).
//!
//! Topology: one `amper replay-serve` process owns the replay memory;
//! N learner processes and M actor-fleet processes connect over TCP or
//! Unix sockets. Each connection is a FIFO command stream, so a single
//! remote learner sees a bit-identical training stream to an
//! in-process one — and extra tenants just interleave at the service's
//! command queue exactly like extra in-process handle clones would.

pub mod client;
pub mod server;
pub mod wire;

pub use client::{
    ClientOptions, ReconnectPolicy, RemoteReplayClient, SnapshotRelay,
};
pub use server::{ClientStats, NetServer, NetServerOptions, TierPort};
pub use wire::{Listener, Opcode, Role, Stream};
