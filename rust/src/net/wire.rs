//! Zero-dependency wire format for the remote replay tier.
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//! u32 len | u8 opcode | u32 client_id | payload (len - 5 bytes)
//! ```
//!
//! `len` counts everything after itself (opcode + client id + payload),
//! so a valid frame always has `len >= 5`; frames past
//! [`MAX_FRAME_LEN`] are rejected before any allocation. Payloads
//! serialize the flat SoA columns of [`ExperienceBatch`] /
//! [`GatheredBatch`] as **contiguous runs** (one per column, no per-row
//! encoding), which keeps encode/decode at memcpy speed and makes the
//! wire image bit-exact: encode→decode reproduces every `f32` by bits.
//!
//! Decoding is strict: every payload's length must match its header
//! fields exactly, trailing bytes are an error, and a corrupt or
//! truncated frame returns `Err` — never a panic, never a partial
//! value. The transport is [`Stream`] / [`Listener`]: TCP
//! (`host:port`) or a Unix socket (`unix:/path`).

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::time::Duration;

use crate::coordinator::PolicySnapshot;
use crate::replay::{ExperienceBatch, GatheredBatch};
use crate::util::error::Result;
use crate::{bail, ensure};

/// Handshake magic ("AMPR") — the first four payload bytes of `Hello`.
pub const MAGIC: u32 = 0x414D_5052;

/// Wire protocol version; bumped on any incompatible layout change.
pub const WIRE_VERSION: u8 = 1;

/// Upper bound on `len` (64 MiB): anything larger is a corrupt or
/// hostile frame and is rejected before any buffer is sized to it.
pub const MAX_FRAME_LEN: usize = 64 << 20;

/// Bytes of frame body (opcode + client id) that `len` always includes.
const FRAME_MIN: usize = 5;

/// Frame opcodes. The numeric values are the wire contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Opcode {
    /// client → server: magic + version + role. First frame on a
    /// connection; anything else is a handshake error.
    Hello = 0x01,
    /// server → client: header `client_id` carries the assigned id;
    /// payload is the server's snapshot epoch marker (0 = none yet,
    /// otherwise `epoch + 1`).
    HelloAck = 0x02,
    /// client → server: an [`ExperienceBatch`] to store (fire-and-forget).
    PushBatch = 0x03,
    /// client → server: request a gathered batch of `n` rows.
    SampleGathered = 0x04,
    /// server → client: the gathered reply columns.
    GatheredOk = 0x05,
    /// server → client: the gather failed; payload is the error text.
    GatheredErr = 0x06,
    /// client → server: TD errors for previously sampled indices.
    UpdatePriorities = 0x07,
    /// learner client → server: publish a policy snapshot to the tier.
    SnapshotPut = 0x08,
    /// server → client: the current policy snapshot (relay push or
    /// `SnapshotGet` reply).
    Snapshot = 0x09,
    /// client → server: send me the snapshot if newer than my marker
    /// (payload: `epoch + 1`, 0 = I have none).
    SnapshotGet = 0x0A,
    /// server → client: `SnapshotGet` reply when nothing newer exists.
    SnapshotNone = 0x0B,
}

impl Opcode {
    pub fn from_u8(b: u8) -> Option<Opcode> {
        Some(match b {
            0x01 => Opcode::Hello,
            0x02 => Opcode::HelloAck,
            0x03 => Opcode::PushBatch,
            0x04 => Opcode::SampleGathered,
            0x05 => Opcode::GatheredOk,
            0x06 => Opcode::GatheredErr,
            0x07 => Opcode::UpdatePriorities,
            0x08 => Opcode::SnapshotPut,
            0x09 => Opcode::Snapshot,
            0x0A => Opcode::SnapshotGet,
            0x0B => Opcode::SnapshotNone,
            _ => return None,
        })
    }
}

/// What a client is to the tier. Learners drive gathers and priority
/// updates and publish snapshots; actors push experiences and receive
/// snapshot relays.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Role {
    Learner = 0,
    Actor = 1,
}

impl Role {
    pub fn from_u8(b: u8) -> Option<Role> {
        match b {
            0 => Some(Role::Learner),
            1 => Some(Role::Actor),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Role::Learner => "learner",
            Role::Actor => "actor",
        }
    }
}

/// Decoded frame header (the payload is returned separately).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameHeader {
    pub opcode: Opcode,
    pub client: u32,
}

/// Write one frame. The payload is whatever an `encode_*` built.
pub fn write_frame(
    w: &mut impl Write,
    opcode: Opcode,
    client: u32,
    payload: &[u8],
) -> Result<()> {
    ensure!(
        payload.len() <= MAX_FRAME_LEN - FRAME_MIN,
        "payload of {} bytes exceeds the frame bound",
        payload.len()
    );
    let len = (payload.len() + FRAME_MIN) as u32;
    let mut head = [0u8; 9];
    head[0..4].copy_from_slice(&len.to_le_bytes());
    head[4] = opcode as u8;
    head[5..9].copy_from_slice(&client.to_le_bytes());
    w.write_all(&head)?;
    w.write_all(payload)?;
    Ok(())
}

/// Read one frame into `payload` (reused across calls — steady-state
/// reads allocate nothing once the buffer has grown). Oversized,
/// undersized, or unknown-opcode frames are `Err`; the caller decides
/// whether that closes the connection. EOF (even at a frame boundary)
/// is an `Err` here — use [`read_frame_opt`] to tell a clean close
/// apart from a malformed stream.
pub fn read_frame(
    r: &mut impl Read,
    payload: &mut Vec<u8>,
) -> Result<FrameHeader> {
    read_frame_opt(r, payload)?
        .ok_or_else(|| crate::err!("connection closed"))
}

/// Like [`read_frame`], but a clean EOF **before any byte of a frame**
/// is `Ok(None)` (the peer hung up between frames) while an EOF
/// mid-frame stays `Err` (the stream was cut or corrupt). Servers use
/// this to close disconnecting clients without charging them a frame
/// error.
pub fn read_frame_opt(
    r: &mut impl Read,
    payload: &mut Vec<u8>,
) -> Result<Option<FrameHeader>> {
    let mut head = [0u8; 4];
    let mut got = 0;
    while got < head.len() {
        match r.read(&mut head[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => bail!("connection cut mid-frame header"),
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    let len = u32::from_le_bytes(head) as usize;
    ensure!(
        (FRAME_MIN..=MAX_FRAME_LEN).contains(&len),
        "frame length {len} outside [{FRAME_MIN}, {MAX_FRAME_LEN}]"
    );
    let mut body = [0u8; FRAME_MIN];
    r.read_exact(&mut body)?;
    let opcode = Opcode::from_u8(body[0])
        .ok_or_else(|| crate::err!("unknown opcode {:#04x}", body[0]))?;
    let client = u32::from_le_bytes([body[1], body[2], body[3], body[4]]);
    payload.resize(len - FRAME_MIN, 0);
    r.read_exact(payload)?;
    Ok(Some(FrameHeader { opcode, client }))
}

// ---------------------------------------------------------------------------
// payload encoding primitives

#[inline]
fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

#[inline]
fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    buf.reserve(xs.len() * 4);
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_u32s(buf: &mut Vec<u8>, xs: &[u32]) {
    buf.reserve(xs.len() * 4);
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_i32s(buf: &mut Vec<u8>, xs: &[i32]) {
    buf.reserve(xs.len() * 4);
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_usizes_as_u64(buf: &mut Vec<u8>, xs: &[usize]) {
    buf.reserve(xs.len() * 8);
    for &x in xs {
        buf.extend_from_slice(&(x as u64).to_le_bytes());
    }
}

/// Bounds-checked payload reader: every `take_*` fails on a short
/// buffer, and [`Reader::finish`] fails on trailing bytes, so a decoded
/// payload is always consumed exactly.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.buf.len() - self.pos >= n,
            "payload truncated: need {n} bytes at offset {}, have {}",
            self.pos,
            self.buf.len() - self.pos
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn take_u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn take_u32(&mut self) -> Result<u32> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn take_u64(&mut self) -> Result<u64> {
        let b = self.bytes(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    /// Fill `out` (pre-sized by the caller) from the next `4 * out.len()`
    /// bytes — the pooled decode path writes straight into recycled
    /// column buffers.
    fn fill_f32s(&mut self, out: &mut [f32]) -> Result<()> {
        let b = self.bytes(out.len() * 4)?;
        for (dst, src) in out.iter_mut().zip(b.chunks_exact(4)) {
            *dst = f32::from_le_bytes([src[0], src[1], src[2], src[3]]);
        }
        Ok(())
    }

    fn fill_i32s(&mut self, out: &mut [i32]) -> Result<()> {
        let b = self.bytes(out.len() * 4)?;
        for (dst, src) in out.iter_mut().zip(b.chunks_exact(4)) {
            *dst = i32::from_le_bytes([src[0], src[1], src[2], src[3]]);
        }
        Ok(())
    }

    fn fill_u64s_as_usize(&mut self, out: &mut [usize]) -> Result<()> {
        let b = self.bytes(out.len() * 8)?;
        for (dst, src) in out.iter_mut().zip(b.chunks_exact(8)) {
            let v = u64::from_le_bytes([
                src[0], src[1], src[2], src[3], src[4], src[5], src[6], src[7],
            ]);
            ensure!(v <= usize::MAX as u64, "index {v:#x} exceeds usize");
            *dst = v as usize;
        }
        Ok(())
    }

    fn take_f32_vec(&mut self, n: usize) -> Result<Vec<f32>> {
        let mut v = vec![0.0f32; n];
        self.fill_f32s(&mut v)?;
        Ok(v)
    }

    fn take_u32_vec(&mut self, n: usize) -> Result<Vec<u32>> {
        let b = self.bytes(n * 4)?;
        Ok(b.chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn finish(self) -> Result<()> {
        ensure!(
            self.pos == self.buf.len(),
            "payload has {} trailing bytes",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// per-opcode payloads

/// `Hello` payload: magic + version + role.
pub fn encode_hello(buf: &mut Vec<u8>, role: Role) {
    buf.clear();
    put_u32(buf, MAGIC);
    buf.push(WIRE_VERSION);
    buf.push(role as u8);
}

pub fn decode_hello(payload: &[u8]) -> Result<Role> {
    let mut r = Reader::new(payload);
    let magic = r.take_u32()?;
    ensure!(magic == MAGIC, "bad handshake magic {magic:#010x}");
    let version = r.take_u8()?;
    ensure!(
        version == WIRE_VERSION,
        "wire version mismatch: peer {version}, local {WIRE_VERSION}"
    );
    let role = r.take_u8()?;
    let role = Role::from_u8(role)
        .ok_or_else(|| crate::err!("unknown client role {role}"))?;
    r.finish()?;
    Ok(role)
}

/// `HelloAck` payload: snapshot epoch marker (0 = no snapshot published
/// yet, otherwise `epoch + 1`). The assigned client id rides in the
/// frame header.
pub fn encode_hello_ack(buf: &mut Vec<u8>, epoch_marker: u64) {
    buf.clear();
    put_u64(buf, epoch_marker);
}

pub fn decode_hello_ack(payload: &[u8]) -> Result<u64> {
    let mut r = Reader::new(payload);
    let m = r.take_u64()?;
    r.finish()?;
    Ok(m)
}

/// `PushBatch` payload: `obs_dim u32, rows u32`, then the five SoA
/// column runs (`obs`, `next_obs` as `rows * obs_dim` f32s each;
/// `actions` u32s; `rewards` f32s; `dones` as one byte per row).
pub fn encode_push_batch(buf: &mut Vec<u8>, b: &ExperienceBatch) {
    buf.clear();
    put_u32(buf, b.obs_dim() as u32);
    put_u32(buf, b.len() as u32);
    put_f32s(buf, b.obs_flat());
    put_f32s(buf, b.next_obs_flat());
    put_u32s(buf, b.actions());
    put_f32s(buf, b.rewards());
    buf.extend(b.dones().iter().map(|&d| d as u8));
}

pub fn decode_push_batch(payload: &[u8]) -> Result<ExperienceBatch> {
    let mut r = Reader::new(payload);
    let obs_dim = r.take_u32()? as usize;
    let rows = r.take_u32()? as usize;
    // exact-size check up front so a corrupt header can never size a
    // large allocation from a small frame
    let want = rows
        .checked_mul(obs_dim)
        .and_then(|od| od.checked_mul(8))
        .and_then(|x| x.checked_add(rows * 9))
        .ok_or_else(|| crate::err!("push-batch shape overflows"))?;
    ensure!(
        r.remaining() == want,
        "push-batch payload holds {} column bytes, want {want} \
         ({rows} rows x {obs_dim} dims)",
        r.remaining()
    );
    let obs = r.take_f32_vec(rows * obs_dim)?;
    let next_obs = r.take_f32_vec(rows * obs_dim)?;
    let actions = r.take_u32_vec(rows)?;
    let rewards = r.take_f32_vec(rows)?;
    let mut dones = Vec::with_capacity(rows);
    for &b in r.bytes(rows)? {
        ensure!(b <= 1, "done flag byte {b} is not 0/1");
        dones.push(b == 1);
    }
    r.finish()?;
    ExperienceBatch::from_columns(obs_dim, obs, next_obs, actions, rewards, dones)
}

/// `SampleGathered` payload: requested batch size.
pub fn encode_sample_gathered(buf: &mut Vec<u8>, batch: u32) {
    buf.clear();
    put_u32(buf, batch);
}

pub fn decode_sample_gathered(payload: &[u8]) -> Result<u32> {
    let mut r = Reader::new(payload);
    let n = r.take_u32()?;
    r.finish()?;
    Ok(n)
}

/// `GatheredOk` payload: `rows u32, obs_dim u32`, then the seven reply
/// column runs (`indices` as u64s, everything else f32/i32).
pub fn encode_gathered(buf: &mut Vec<u8>, g: &GatheredBatch) {
    buf.clear();
    put_u32(buf, g.rows() as u32);
    put_u32(buf, g.obs_dim() as u32);
    put_usizes_as_u64(buf, &g.indices);
    put_f32s(buf, &g.is_weights);
    put_f32s(buf, &g.obs);
    put_i32s(buf, &g.actions);
    put_f32s(buf, &g.rewards);
    put_f32s(buf, &g.next_obs);
    put_f32s(buf, &g.dones);
}

/// Decode a `GatheredOk` payload **into** `g` (a pooled buffer on the
/// steady-state path): `reset` sizes every column in place, then each
/// run is filled by one bounds-checked pass.
pub fn decode_gathered_into(payload: &[u8], g: &mut GatheredBatch) -> Result<()> {
    let mut r = Reader::new(payload);
    let rows = r.take_u32()? as usize;
    let obs_dim = r.take_u32()? as usize;
    let want = rows
        .checked_mul(obs_dim)
        .and_then(|od| od.checked_mul(8))
        .and_then(|x| x.checked_add(rows * 24))
        .ok_or_else(|| crate::err!("gathered shape overflows"))?;
    ensure!(
        r.remaining() == want,
        "gathered payload holds {} column bytes, want {want} \
         ({rows} rows x {obs_dim} dims)",
        r.remaining()
    );
    g.reset(rows, obs_dim);
    r.fill_u64s_as_usize(&mut g.indices)?;
    r.fill_f32s(&mut g.is_weights)?;
    r.fill_f32s(&mut g.obs)?;
    r.fill_i32s(&mut g.actions)?;
    r.fill_f32s(&mut g.rewards)?;
    r.fill_f32s(&mut g.next_obs)?;
    r.fill_f32s(&mut g.dones)?;
    r.finish()
}

/// Allocating convenience over [`decode_gathered_into`] (tests).
pub fn decode_gathered(payload: &[u8]) -> Result<GatheredBatch> {
    let mut g = GatheredBatch::default();
    decode_gathered_into(payload, &mut g)?;
    Ok(g)
}

/// `GatheredErr` payload: the error message, UTF-8.
pub fn encode_gathered_err(buf: &mut Vec<u8>, msg: &str) {
    buf.clear();
    buf.extend_from_slice(msg.as_bytes());
}

pub fn decode_gathered_err(payload: &[u8]) -> Result<String> {
    Ok(String::from_utf8_lossy(payload).into_owned())
}

/// `UpdatePriorities` payload: `n u32`, indices as u64s, TD errors as
/// f32s.
pub fn encode_update_priorities(
    buf: &mut Vec<u8>,
    indices: &[usize],
    td: &[f32],
) {
    debug_assert_eq!(indices.len(), td.len());
    buf.clear();
    put_u32(buf, indices.len() as u32);
    put_usizes_as_u64(buf, indices);
    put_f32s(buf, td);
}

pub fn decode_update_priorities(
    payload: &[u8],
) -> Result<(Vec<usize>, Vec<f32>)> {
    let mut r = Reader::new(payload);
    let n = r.take_u32()? as usize;
    let want = n
        .checked_mul(12)
        .ok_or_else(|| crate::err!("priority-update shape overflows"))?;
    ensure!(
        r.remaining() == want,
        "priority-update payload holds {} bytes, want {want} ({n} entries)",
        r.remaining()
    );
    let mut indices = vec![0usize; n];
    r.fill_u64s_as_usize(&mut indices)?;
    let td = r.take_f32_vec(n)?;
    r.finish()?;
    Ok((indices, td))
}

/// `SnapshotPut` / `Snapshot` payload: `epoch u64`, dims (`count u32` +
/// u32s), params (`count u32` + per-param `len u32` + f32 run). Decoding
/// goes through [`PolicySnapshot::new`], so a structurally valid frame
/// with inconsistent shapes is still rejected.
pub fn encode_snapshot(buf: &mut Vec<u8>, snap: &PolicySnapshot) {
    buf.clear();
    put_u64(buf, snap.epoch());
    put_u32(buf, snap.dims().len() as u32);
    for &d in snap.dims() {
        put_u32(buf, d as u32);
    }
    put_u32(buf, snap.params().len() as u32);
    for p in snap.params() {
        put_u32(buf, p.len() as u32);
        put_f32s(buf, p);
    }
}

pub fn decode_snapshot(payload: &[u8]) -> Result<PolicySnapshot> {
    let mut r = Reader::new(payload);
    let epoch = r.take_u64()?;
    let n_dims = r.take_u32()? as usize;
    ensure!(n_dims <= 16, "snapshot claims {n_dims} dims");
    let mut dims = Vec::with_capacity(n_dims);
    for _ in 0..n_dims {
        dims.push(r.take_u32()? as usize);
    }
    let n_params = r.take_u32()? as usize;
    ensure!(n_params <= 16, "snapshot claims {n_params} params");
    let mut params = Vec::with_capacity(n_params);
    for _ in 0..n_params {
        let len = r.take_u32()? as usize;
        ensure!(
            len * 4 <= r.remaining(),
            "snapshot param run of {len} floats overruns the payload"
        );
        params.push(r.take_f32_vec(len)?);
    }
    r.finish()?;
    PolicySnapshot::new(params, dims, epoch)
}

/// `SnapshotGet` payload: the requester's epoch marker (0 = none,
/// otherwise `epoch + 1`); the server replies `Snapshot` only if its
/// marker is higher.
pub fn encode_snapshot_get(buf: &mut Vec<u8>, epoch_marker: u64) {
    buf.clear();
    put_u64(buf, epoch_marker);
}

pub fn decode_snapshot_get(payload: &[u8]) -> Result<u64> {
    let mut r = Reader::new(payload);
    let m = r.take_u64()?;
    r.finish()?;
    Ok(m)
}

// ---------------------------------------------------------------------------
// transport

/// One duplex byte stream: TCP or Unix socket.
#[derive(Debug)]
pub enum Stream {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl Stream {
    /// Connect to `addr`: `unix:/path` for a Unix socket, otherwise
    /// `host:port` TCP (with `TCP_NODELAY` — frames are latency-bound
    /// request/reply units, not bulk flows).
    pub fn connect(addr: &str) -> Result<Stream> {
        if let Some(path) = addr.strip_prefix("unix:") {
            Ok(Stream::Unix(UnixStream::connect(path)?))
        } else {
            let s = TcpStream::connect(addr)?;
            s.set_nodelay(true)?;
            Ok(Stream::Tcp(s))
        }
    }

    /// A second handle onto the same socket (reader/writer split).
    pub fn try_clone(&self) -> Result<Stream> {
        Ok(match self {
            Stream::Tcp(s) => Stream::Tcp(s.try_clone()?),
            Stream::Unix(s) => Stream::Unix(s.try_clone()?),
        })
    }

    /// Shut both directions down, unblocking any reader on the peer or
    /// a clone of this stream. Errors ignored: shutting down an
    /// already-dead socket is the common case on the close path.
    pub fn shutdown(&self) {
        let _ = match self {
            Stream::Tcp(s) => s.shutdown(std::net::Shutdown::Both),
            Stream::Unix(s) => s.shutdown(std::net::Shutdown::Both),
        };
    }

    /// Bound blocking writes (a stalled peer fails instead of wedging
    /// the writer forever). `None` = block indefinitely.
    pub fn set_write_timeout(&self, t: Option<Duration>) -> Result<()> {
        match self {
            Stream::Tcp(s) => s.set_write_timeout(t)?,
            Stream::Unix(s) => s.set_write_timeout(t)?,
        }
        Ok(())
    }

    /// Bound blocking reads. `None` = block indefinitely.
    pub fn set_read_timeout(&self, t: Option<Duration>) -> Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(t)?,
            Stream::Unix(s) => s.set_read_timeout(t)?,
        }
        Ok(())
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// Listening socket for the replay tier: TCP or Unix.
#[derive(Debug)]
pub enum Listener {
    Tcp(TcpListener),
    Unix(UnixListener),
}

impl Listener {
    /// Bind `addr` (same syntax as [`Stream::connect`]; for TCP, port 0
    /// picks a free port — read it back via [`Listener::local_addr`]).
    pub fn bind(addr: &str) -> Result<Listener> {
        if let Some(path) = addr.strip_prefix("unix:") {
            // a stale socket file from a previous tier blocks the bind
            let _ = std::fs::remove_file(path);
            Ok(Listener::Unix(UnixListener::bind(path)?))
        } else {
            Ok(Listener::Tcp(TcpListener::bind(addr)?))
        }
    }

    /// The bound address in [`Stream::connect`] syntax.
    pub fn local_addr(&self) -> Result<String> {
        Ok(match self {
            Listener::Tcp(l) => l.local_addr()?.to_string(),
            Listener::Unix(l) => {
                let a = l.local_addr()?;
                let path = a
                    .as_pathname()
                    .ok_or_else(|| crate::err!("unnamed unix listener"))?;
                format!("unix:{}", path.display())
            }
        })
    }

    /// Accept one connection (respects `set_nonblocking`).
    pub fn accept(&self) -> std::io::Result<Stream> {
        Ok(match self {
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                s.set_nodelay(true)?;
                Stream::Tcp(s)
            }
            Listener::Unix(l) => {
                let (s, _) = l.accept()?;
                Stream::Unix(s)
            }
        })
    }

    pub fn set_nonblocking(&self, nb: bool) -> Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(nb)?,
            Listener::Unix(l) => l.set_nonblocking(nb)?,
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::Experience;

    fn batch(rows: usize, dim: usize) -> ExperienceBatch {
        let exps: Vec<Experience> = (0..rows)
            .map(|i| Experience {
                obs: (0..dim).map(|d| (i * dim + d) as f32 * 0.5).collect(),
                action: i as u32,
                reward: i as f32 - 1.5,
                next_obs: (0..dim).map(|d| (i * dim + d) as f32 + 0.25).collect(),
                done: i % 3 == 0,
            })
            .collect();
        ExperienceBatch::from_experiences(&exps)
    }

    #[test]
    fn frame_roundtrip_over_a_byte_pipe() {
        let mut wire = Vec::new();
        write_frame(&mut wire, Opcode::PushBatch, 7, &[1, 2, 3]).unwrap();
        write_frame(&mut wire, Opcode::SampleGathered, 9, &[]).unwrap();
        let mut r = &wire[..];
        let mut payload = Vec::new();
        let h = read_frame(&mut r, &mut payload).unwrap();
        assert_eq!(h, FrameHeader { opcode: Opcode::PushBatch, client: 7 });
        assert_eq!(payload, vec![1, 2, 3]);
        let h = read_frame(&mut r, &mut payload).unwrap();
        assert_eq!(h.opcode, Opcode::SampleGathered);
        assert_eq!(h.client, 9);
        assert!(payload.is_empty());
        assert!(r.is_empty());
    }

    #[test]
    fn truncated_and_oversized_frames_error() {
        let mut wire = Vec::new();
        write_frame(&mut wire, Opcode::Hello, 0, &[0; 16]).unwrap();
        let mut payload = Vec::new();
        // every possible truncation point fails cleanly
        for cut in 0..wire.len() {
            let mut r = &wire[..cut];
            assert!(read_frame(&mut r, &mut payload).is_err(), "cut {cut}");
        }
        // a length below the frame minimum
        let mut r = &3u32.to_le_bytes()[..];
        assert!(read_frame(&mut r, &mut payload).is_err());
        // a hostile length: rejected before any allocation
        let mut bad = ((MAX_FRAME_LEN + 1) as u32).to_le_bytes().to_vec();
        bad.extend_from_slice(&[0; 8]);
        let mut r = &bad[..];
        assert!(read_frame(&mut r, &mut payload).is_err());
        // an unknown opcode
        let mut bad = Vec::new();
        write_frame(&mut bad, Opcode::Hello, 0, &[]).unwrap();
        bad[4] = 0xEE;
        let mut r = &bad[..];
        assert!(read_frame(&mut r, &mut payload).is_err());
    }

    #[test]
    fn push_batch_roundtrip_bit_identical() {
        let b = batch(13, 3);
        let mut buf = Vec::new();
        encode_push_batch(&mut buf, &b);
        let d = decode_push_batch(&buf).unwrap();
        assert_eq!(d, b);
        // empty batch (flush of nothing) survives too
        encode_push_batch(&mut buf, &ExperienceBatch::new(4));
        let d = decode_push_batch(&buf).unwrap();
        assert!(d.is_empty());
        assert_eq!(d.obs_dim(), 4);
    }

    #[test]
    fn push_batch_rejects_corrupt_payloads() {
        let b = batch(4, 2);
        let mut buf = Vec::new();
        encode_push_batch(&mut buf, &b);
        assert!(decode_push_batch(&buf[..buf.len() - 1]).is_err(), "short");
        let mut long = buf.clone();
        long.push(0);
        assert!(decode_push_batch(&long).is_err(), "trailing byte");
        let mut bad_done = buf.clone();
        *bad_done.last_mut().unwrap() = 7;
        assert!(decode_push_batch(&bad_done).is_err(), "done byte not 0/1");
        // rows field inflated: must fail the exact-size check, not allocate
        let mut bad_rows = buf.clone();
        bad_rows[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_push_batch(&bad_rows).is_err());
    }

    #[test]
    fn gathered_roundtrip_bit_identical_and_pooled_decode_reuses() {
        let mut g = GatheredBatch::default();
        g.reset(6, 3);
        for (i, x) in g.obs.iter_mut().enumerate() {
            *x = i as f32 * 0.75;
        }
        g.indices.copy_from_slice(&[5, 0, 3, 9, 2, 7]);
        g.is_weights.fill(0.125);
        g.dones[1] = 1.0;
        let mut buf = Vec::new();
        encode_gathered(&mut buf, &g);
        let d = decode_gathered(&buf).unwrap();
        assert_eq!(d, g);
        // pooled path: decode into a warm buffer without reallocating
        let mut warm = GatheredBatch::default();
        warm.reset(6, 3);
        let ptr = warm.obs.as_ptr();
        decode_gathered_into(&buf, &mut warm).unwrap();
        assert_eq!(warm, g);
        assert_eq!(warm.obs.as_ptr(), ptr, "pooled decode must not realloc");
        // corrupt length
        assert!(decode_gathered(&buf[..buf.len() - 2]).is_err());
    }

    #[test]
    fn update_priorities_roundtrip() {
        let idx = vec![0usize, 42, (u32::MAX as usize) << 20];
        let td = vec![0.5f32, -1.25, f32::MIN_POSITIVE];
        let mut buf = Vec::new();
        encode_update_priorities(&mut buf, &idx, &td);
        let (di, dt) = decode_update_priorities(&buf).unwrap();
        assert_eq!(di, idx);
        assert_eq!(
            dt.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            td.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert!(decode_update_priorities(&buf[..buf.len() - 3]).is_err());
    }

    #[test]
    fn hello_and_ack_roundtrip_and_validate() {
        let mut buf = Vec::new();
        encode_hello(&mut buf, Role::Actor);
        assert_eq!(decode_hello(&buf).unwrap(), Role::Actor);
        let mut bad = buf.clone();
        bad[0] ^= 0xFF;
        assert!(decode_hello(&bad).is_err(), "bad magic");
        let mut bad = buf.clone();
        bad[4] = WIRE_VERSION + 1;
        assert!(decode_hello(&bad).is_err(), "version skew");
        let mut bad = buf.clone();
        bad[5] = 9;
        assert!(decode_hello(&bad).is_err(), "unknown role");
        encode_hello_ack(&mut buf, 17);
        assert_eq!(decode_hello_ack(&buf).unwrap(), 17);
    }

    #[test]
    fn snapshot_roundtrip_via_policy_validation() {
        use crate::runtime::{EnvArtifacts, TrainState};
        let spec = EnvArtifacts::builtin("cartpole").unwrap();
        let state = TrainState::init(&spec, 3).unwrap();
        let snap =
            PolicySnapshot::new(state.snapshot_params(), spec.dims.clone(), 12)
                .unwrap();
        let mut buf = Vec::new();
        encode_snapshot(&mut buf, &snap);
        let d = decode_snapshot(&buf).unwrap();
        assert_eq!(d.epoch(), 12);
        assert_eq!(d.dims(), snap.dims());
        for (a, b) in d.params().iter().zip(snap.params()) {
            assert_eq!(
                a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
        }
        // truncations surface as Err, never panic
        for cut in [0, 7, 8, 9, buf.len() - 1] {
            assert!(decode_snapshot(&buf[..cut]).is_err(), "cut {cut}");
        }
        // a wrong dim count is caught by PolicySnapshot::new
        let mut bad = buf.clone();
        bad[8..12].copy_from_slice(&3u32.to_le_bytes());
        assert!(decode_snapshot(&bad).is_err());
    }

    #[test]
    fn tcp_stream_carries_frames() {
        let l = Listener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let mut c = Stream::connect(&addr).unwrap();
            let mut buf = Vec::new();
            encode_sample_gathered(&mut buf, 64);
            write_frame(&mut c, Opcode::SampleGathered, 3, &buf).unwrap();
        });
        let mut s = l.accept().unwrap();
        let mut payload = Vec::new();
        let h = read_frame(&mut s, &mut payload).unwrap();
        assert_eq!(h.opcode, Opcode::SampleGathered);
        assert_eq!(h.client, 3);
        assert_eq!(decode_sample_gathered(&payload).unwrap(), 64);
        t.join().unwrap();
    }

    #[test]
    fn unix_listener_binds_and_reports_addr() {
        let path = std::env::temp_dir().join(format!(
            "amper-wire-test-{}.sock",
            std::process::id()
        ));
        let addr = format!("unix:{}", path.display());
        let l = Listener::bind(&addr).unwrap();
        assert_eq!(l.local_addr().unwrap(), addr);
        let t = {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = Stream::connect(&addr).unwrap();
                write_frame(&mut c, Opcode::Hello, 0, &[]).unwrap();
            })
        };
        let mut s = l.accept().unwrap();
        let mut payload = Vec::new();
        assert_eq!(
            read_frame(&mut s, &mut payload).unwrap().opcode,
            Opcode::Hello
        );
        t.join().unwrap();
        let _ = std::fs::remove_file(&path);
    }
}
