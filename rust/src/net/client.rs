//! Client side of the wire protocol: [`RemoteReplayClient`] implements
//! [`ReplaySink`] and [`LearnerPort`], so the existing actor drivers
//! (`VecEnvTicker`, `VectorEnvDriver`), the pipelined learner
//! (`GatherPipeline`), and the serve learner loop all run against a
//! remote replay tier **unmodified** — the process boundary is just
//! another handle shape.
//!
//! One connection carries one FIFO command stream: requests are framed
//! in issue order while a per-connection reader thread matches
//! `GatheredOk` / `GatheredErr` replies to waiters front-of-queue, so
//! the remote service observes commands in exactly the order an
//! in-process handle would deliver them (which is what makes the N=1
//! remote stream bit-identical to `amper serve`).
//!
//! Reconnect: a dead connection is reopened on the next request with
//! capped exponential backoff ([`ReconnectPolicy`]); the handshake is
//! redone and the client resyncs its snapshot mirror by asking the tier
//! for anything newer than what it already holds. Requests that were in
//! flight when the connection died resolve to `Err` (their waiters see
//! a disconnected reply channel) — they are **not** replayed, because
//! the tier may or may not have executed them.
//!
//! Zero-copy: gathered replies decode into buffers drawn from a
//! client-local [`ReplyPool`] and are recycled by the learner exactly
//! like in-process replies. One accounting asymmetry is inherent to the
//! wire: the pool `take` happens when the *reply* arrives (reader
//! thread), not when the request is issued, while a timed-out waiter
//! still records `note_lost`. So on this pool `hits + misses` can run
//! *behind* `recycled + dropped` after faults — assert `taken <=
//! settled` here, not equality (the server's per-client pools keep the
//! exact identity).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::mpsc::{sync_channel, SendError, SyncSender};
use std::sync::{Arc, Mutex, MutexGuard, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::wire::{self, read_frame_opt, write_frame, Opcode, Role, Stream};
use crate::coordinator::pool::PendingInner;
use crate::coordinator::service::{DEFAULT_GATHER_TIMEOUT_MS, DEFAULT_REPLY_POOL};
use crate::coordinator::{
    GatheredBatch, LearnerPort, PendingGather, PolicySnapshot, ReplaySink,
    ReplyPool, ServiceStats, SnapshotSlot,
};
use crate::replay::{Experience, ExperienceBatch};
use crate::util::error::{Error, Result};
use crate::ensure;

/// Capped exponential backoff for reconnect attempts: `base`, `2·base`,
/// `4·base`, … clamped to `max`, giving up after `tries` failures.
#[derive(Debug, Clone)]
pub struct ReconnectPolicy {
    pub base: Duration,
    pub max: Duration,
    pub tries: u32,
}

impl Default for ReconnectPolicy {
    fn default() -> ReconnectPolicy {
        ReconnectPolicy {
            base: Duration::from_millis(50),
            max: Duration::from_millis(2000),
            tries: 10,
        }
    }
}

/// Tuning for [`RemoteReplayClient::connect_with`].
#[derive(Debug, Clone)]
pub struct ClientOptions {
    pub reconnect: ReconnectPolicy,
    /// Bound on one gathered-reply wait (mirrors the in-process
    /// handle's gather timeout).
    pub gather_timeout: Duration,
    /// Idle buffers retained in the client-local reply pool.
    pub reply_pool: usize,
}

impl Default for ClientOptions {
    fn default() -> ClientOptions {
        ClientOptions {
            reconnect: ReconnectPolicy::default(),
            gather_timeout: Duration::from_millis(DEFAULT_GATHER_TIMEOUT_MS),
            reply_pool: DEFAULT_REPLY_POOL,
        }
    }
}

/// Reply waiters for one connection, matched FIFO by the reader thread.
type Pending = Mutex<VecDeque<SyncSender<Result<GatheredBatch>>>>;

/// Mutable connection state behind one lock: the writer half, the
/// pending-reply queue of the *current* connection (readers of older
/// connections see a stale `gen` and leave the new state alone), and
/// the encode scratch buffer.
struct ConnState {
    stream: Option<Stream>,
    pending: Arc<Pending>,
    scratch: Vec<u8>,
    /// Bumped on every successful (re)connect.
    gen: u64,
}

struct ClientInner {
    addr: String,
    role: Role,
    policy: ReconnectPolicy,
    timeout: Duration,
    conn: Mutex<ConnState>,
    /// Client-local gathered-reply pool (see module docs for the
    /// accounting asymmetry).
    pool: ReplyPool,
    /// Client-local counters in the same shape as a service's, so
    /// generic serving loops print the same operability report.
    stats: Arc<ServiceStats>,
    /// Snapshot mirror: populated from relayed `Snapshot` frames; actors
    /// read policies from here exactly as from an in-process slot.
    slot: Mutex<Option<Arc<SnapshotSlot>>>,
    client_id: AtomicU32,
    stop: AtomicBool,
}

impl ClientInner {
    fn mirror_marker(&self) -> u64 {
        self.slot
            .lock()
            .expect("snapshot mirror poisoned")
            .as_ref()
            .map(|s| s.epoch().saturating_add(1))
            .unwrap_or(0)
    }

    /// Install a relayed snapshot into the mirror: the first one creates
    /// the slot (teaching this process the policy dims), later ones go
    /// through `SnapshotSlot::install` (newer-epoch-wins, so replays and
    /// double relays are harmless).
    fn install_snapshot(&self, snap: PolicySnapshot) {
        let mut slot = self.slot.lock().expect("snapshot mirror poisoned");
        match slot.as_ref() {
            Some(s) => {
                s.install(snap);
            }
            None => {
                *slot = Some(SnapshotSlot::with_stats(
                    snap,
                    Arc::clone(&self.stats.snapshot),
                ));
            }
        }
    }

    fn teardown(conn: &mut ConnState) {
        if let Some(s) = conn.stream.take() {
            s.shutdown();
        }
    }
}

impl Drop for ClientInner {
    fn drop(&mut self) {
        // shut the socket so the reader thread (which holds only a Weak
        // to us) unblocks and exits
        if let Ok(mut conn) = self.conn.lock() {
            ClientInner::teardown(&mut conn);
        }
    }
}

/// A replay-service handle whose service lives in another process.
/// Cheap to clone; clones share one connection, one reply pool, and one
/// snapshot mirror.
#[derive(Clone)]
pub struct RemoteReplayClient {
    inner: Arc<ClientInner>,
}

impl RemoteReplayClient {
    /// Connect to a replay tier at `addr` (`host:port` or `unix:/path`)
    /// with default options. Fails fast if the tier is unreachable.
    pub fn connect(addr: &str, role: Role) -> Result<RemoteReplayClient> {
        Self::connect_with(addr, role, ClientOptions::default())
    }

    pub fn connect_with(
        addr: &str,
        role: Role,
        opts: ClientOptions,
    ) -> Result<RemoteReplayClient> {
        let client = RemoteReplayClient {
            inner: Arc::new(ClientInner {
                addr: addr.to_string(),
                role,
                policy: opts.reconnect,
                timeout: opts.gather_timeout,
                conn: Mutex::new(ConnState {
                    stream: None,
                    pending: Arc::new(Mutex::new(VecDeque::new())),
                    scratch: Vec::new(),
                    gen: 0,
                }),
                pool: ReplyPool::new(opts.reply_pool),
                stats: Arc::new(ServiceStats::default()),
                slot: Mutex::new(None),
                client_id: AtomicU32::new(0),
                stop: AtomicBool::new(false),
            }),
        };
        {
            let mut conn = client.locked_conn();
            client.open_locked(&mut conn)?;
        }
        Ok(client)
    }

    /// The handshake-assigned client id (0 before the first connect
    /// completes — never handed out by a server).
    pub fn client_id(&self) -> u32 {
        self.inner.client_id.load(Ordering::Relaxed)
    }

    pub fn role(&self) -> Role {
        self.inner.role
    }

    /// Close the connection and refuse further reconnects. In-flight
    /// requests resolve to `Err`.
    pub fn close(&self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        let mut conn = self.locked_conn();
        ClientInner::teardown(&mut conn);
    }

    /// The snapshot mirror, once the tier has relayed at least one
    /// snapshot (`None` before that — a fresh tier knows no policy).
    pub fn snapshot_slot(&self) -> Option<Arc<SnapshotSlot>> {
        self.inner.slot.lock().expect("snapshot mirror poisoned").clone()
    }

    /// Block until the tier relays a first policy snapshot (an actor
    /// joining an already-warm tier gets it at handshake; one joining a
    /// cold tier polls with `SnapshotGet` until a learner publishes).
    pub fn wait_snapshot_slot(
        &self,
        timeout: Duration,
    ) -> Result<Arc<SnapshotSlot>> {
        let deadline = Instant::now() + timeout;
        drop(self.ensure_conn()?);
        let mut next_ask = Instant::now();
        loop {
            if let Some(slot) = self.snapshot_slot() {
                return Ok(slot);
            }
            ensure!(
                Instant::now() < deadline,
                "no policy snapshot relayed within {timeout:?}"
            );
            if Instant::now() >= next_ask {
                let have = self.inner.mirror_marker();
                let _ = self.send_frame(Opcode::SnapshotGet, &|buf| {
                    wire::encode_snapshot_get(buf, have)
                });
                next_ask = Instant::now() + Duration::from_millis(50);
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    /// Publish every epoch `slot` reaches (including the one it holds
    /// right now — the epoch-0 initial snapshot is what teaches a cold
    /// tier the policy dims) to the tier as `SnapshotPut`, from a
    /// background thread. Dropping the returned guard stops the relay.
    pub fn relay_snapshots(&self, slot: Arc<SnapshotSlot>) -> SnapshotRelay {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let client = self.clone();
        let handle = std::thread::Builder::new()
            .name("replay-net-relay".into())
            .spawn(move || {
                let mut sent = 0u64;
                while !flag.load(Ordering::Relaxed)
                    && !client.inner.stop.load(Ordering::Relaxed)
                {
                    let marker = slot.epoch().saturating_add(1);
                    if marker > sent {
                        let snap = slot.load();
                        let ok = client
                            .send_frame(Opcode::SnapshotPut, &|buf| {
                                wire::encode_snapshot(buf, &snap)
                            })
                            .is_ok();
                        if ok {
                            sent = marker;
                        } else {
                            std::thread::sleep(Duration::from_millis(20));
                        }
                    } else {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                }
            })
            .expect("spawn snapshot relay thread");
        SnapshotRelay { stop, handle: Some(handle) }
    }

    fn locked_conn(&self) -> MutexGuard<'_, ConnState> {
        self.inner.conn.lock().expect("net client state poisoned")
    }

    /// Dial, handshake, resync the snapshot mirror, and spawn the reader
    /// for one fresh connection. Called with the conn lock held.
    fn open_locked(&self, conn: &mut ConnState) -> Result<()> {
        let mut stream = Stream::connect(&self.inner.addr)?;
        wire::encode_hello(&mut conn.scratch, self.inner.role);
        write_frame(&mut stream, Opcode::Hello, 0, &conn.scratch)?;
        let mut payload = Vec::new();
        let header = wire::read_frame(&mut stream, &mut payload)?;
        ensure!(
            header.opcode == Opcode::HelloAck,
            "expected HelloAck, got {:?}",
            header.opcode
        );
        wire::decode_hello_ack(&payload)?;
        self.inner.client_id.store(header.client, Ordering::Relaxed);
        // resync: ask for any snapshot newer than the mirror's (after a
        // reconnect this refreshes a stale mirror in one round trip)
        wire::encode_snapshot_get(&mut conn.scratch, self.inner.mirror_marker());
        write_frame(&mut stream, Opcode::SnapshotGet, header.client, &conn.scratch)?;

        conn.gen += 1;
        conn.pending = Arc::new(Mutex::new(VecDeque::new()));
        let reader_stream = stream.try_clone()?;
        conn.stream = Some(stream);
        let weak = Arc::downgrade(&self.inner);
        let pool = self.inner.pool.clone();
        let pending = Arc::clone(&conn.pending);
        let gen = conn.gen;
        std::thread::Builder::new()
            .name("replay-net-reader".into())
            .spawn(move || reader_loop(weak, pool, reader_stream, pending, gen))
            .map_err(|e| crate::err!("spawn net reader: {e}"))?;
        Ok(())
    }

    /// Lock the connection, reconnecting with capped exponential backoff
    /// if it is down. Holds the lock across the backoff — clones that
    /// pile up behind it would only rediscover the same dead tier.
    fn ensure_conn(&self) -> Result<MutexGuard<'_, ConnState>> {
        let mut conn = self.locked_conn();
        if conn.stream.is_some() {
            return Ok(conn);
        }
        ensure!(
            !self.inner.stop.load(Ordering::Relaxed),
            "remote replay client is closed"
        );
        let p = &self.inner.policy;
        let mut delay = p.base;
        let mut attempt = 0u32;
        loop {
            match self.open_locked(&mut conn) {
                Ok(()) => return Ok(conn),
                Err(e) => {
                    attempt += 1;
                    if attempt > p.tries {
                        return Err(e);
                    }
                    ensure!(
                        !self.inner.stop.load(Ordering::Relaxed),
                        "remote replay client is closed"
                    );
                    std::thread::sleep(delay.min(p.max));
                    delay = delay.saturating_mul(2).min(p.max);
                }
            }
        }
    }

    /// Encode with `build` and write one frame, reconnecting and
    /// retrying once if the write finds the connection dead.
    fn send_frame(
        &self,
        opcode: Opcode,
        build: &dyn Fn(&mut Vec<u8>),
    ) -> Result<()> {
        for attempt in 0..2 {
            let mut conn = self.ensure_conn()?;
            let id = self.inner.client_id.load(Ordering::Relaxed);
            let ConnState { stream, scratch, .. } = &mut *conn;
            build(scratch);
            match write_frame(stream.as_mut().expect("ensured"), opcode, id, scratch)
            {
                Ok(()) => return Ok(()),
                Err(e) => {
                    ClientInner::teardown(&mut conn);
                    if attempt == 1 {
                        return Err(e);
                    }
                }
            }
        }
        unreachable!("send_frame returns from inside the loop")
    }
}

/// Guard for a running snapshot relay thread; dropping it stops the
/// relay and joins the thread.
pub struct SnapshotRelay {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl Drop for SnapshotRelay {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One connection's reply demultiplexer. Holds only a `Weak` to the
/// client so dropping the last handle shuts the socket (via
/// `ClientInner::drop`) and this thread exits instead of pinning the
/// client alive.
fn reader_loop(
    weak: Weak<ClientInner>,
    pool: ReplyPool,
    mut stream: Stream,
    pending: Arc<Pending>,
    gen: u64,
) {
    let mut payload = Vec::new();
    loop {
        let header = match read_frame_opt(&mut stream, &mut payload) {
            Ok(Some(h)) => h,
            _ => break,
        };
        match header.opcode {
            Opcode::GatheredOk => {
                let mut g = pool.take().unwrap_or_default();
                if wire::decode_gathered_into(&payload, &mut g).is_err() {
                    pool.put(g);
                    break;
                }
                match pending.lock().expect("pending poisoned").pop_front() {
                    Some(tx) => {
                        if let Err(SendError(res)) = tx.send(Ok(g)) {
                            // the waiter timed out and left; keep the buffer
                            if let Ok(g) = res {
                                pool.put(g);
                            }
                        }
                    }
                    // a reply with no request outstanding: desynced stream
                    None => {
                        pool.put(g);
                        break;
                    }
                }
            }
            Opcode::GatheredErr => {
                let msg = wire::decode_gathered_err(&payload)
                    .unwrap_or_else(|_| "remote gather failed".to_string());
                match pending.lock().expect("pending poisoned").pop_front() {
                    Some(tx) => {
                        let _ = tx.send(Err(Error::msg(msg)));
                    }
                    None => break,
                }
            }
            Opcode::Snapshot => {
                let Some(inner) = weak.upgrade() else { break };
                match wire::decode_snapshot(&payload) {
                    Ok(snap) => inner.install_snapshot(snap),
                    Err(_) => break,
                }
            }
            Opcode::SnapshotNone => {}
            // client-bound streams carry only replies and snapshot relays
            _ => break,
        }
    }
    // fail every request still in flight on this connection: dropping
    // the senders disconnects the waiters, whose `wait` settles the
    // pool accounting via note_lost
    pending.lock().expect("pending poisoned").clear();
    // mark the connection dead unless a newer one already replaced it
    if let Some(inner) = weak.upgrade() {
        if let Ok(mut conn) = inner.conn.lock() {
            if conn.gen == gen {
                ClientInner::teardown(&mut conn);
            }
        }
    }
}

impl ReplaySink for RemoteReplayClient {
    fn push_experience(&self, e: Experience) -> bool {
        self.push_experience_batch(ExperienceBatch::from_experience(e))
    }

    fn push_experience_batch(&self, batch: ExperienceBatch) -> bool {
        if batch.is_empty() {
            return true;
        }
        let rows = batch.len() as u64;
        let ok = self
            .send_frame(Opcode::PushBatch, &|buf| {
                wire::encode_push_batch(buf, &batch)
            })
            .is_ok();
        if ok {
            self.inner.stats.pushes.fetch_add(rows, Ordering::Relaxed);
        }
        ok
    }
}

impl LearnerPort for RemoteReplayClient {
    fn request_gathered(&self, batch: usize) -> PendingGather {
        let dead = || PendingGather { inner: PendingInner::Dead };
        let (tx, rx) = sync_channel::<Result<GatheredBatch>>(1);
        for attempt in 0..2 {
            let mut conn = match self.ensure_conn() {
                Ok(c) => c,
                Err(_) => return dead(),
            };
            let id = self.inner.client_id.load(Ordering::Relaxed);
            conn.pending
                .lock()
                .expect("pending poisoned")
                .push_back(tx.clone());
            let ConnState { stream, scratch, pending, .. } = &mut *conn;
            wire::encode_sample_gathered(scratch, batch.min(u32::MAX as usize) as u32);
            match write_frame(
                stream.as_mut().expect("ensured"),
                Opcode::SampleGathered,
                id,
                scratch,
            ) {
                Ok(()) => {
                    self.inner.stats.samples.fetch_add(1, Ordering::Relaxed);
                    return PendingGather {
                        inner: PendingInner::Single {
                            rx,
                            timeout: self.inner.timeout,
                            pool: self.inner.pool.clone(),
                            stats: Arc::clone(&self.inner.stats),
                        },
                    };
                }
                Err(_) => {
                    // the request never left: take our waiter back out
                    pending.lock().expect("pending poisoned").pop_back();
                    ClientInner::teardown(&mut conn);
                    if attempt == 1 {
                        return dead();
                    }
                }
            }
        }
        unreachable!("request_gathered returns from inside the loop")
    }

    fn recycle(&self, buf: GatheredBatch) {
        self.inner.pool.put(buf);
    }

    fn reply_pool(&self) -> &ReplyPool {
        &self.inner.pool
    }

    fn update_priorities(&self, indices: Vec<usize>, td: Vec<f32>) -> bool {
        if indices.is_empty() {
            return true;
        }
        let ok = self
            .send_frame(Opcode::UpdatePriorities, &|buf| {
                wire::encode_update_priorities(buf, &indices, &td)
            })
            .is_ok();
        if ok {
            self.inner.stats.updates.fetch_add(1, Ordering::Relaxed);
        }
        ok
    }

    fn service_stats(&self) -> &ServiceStats {
        &self.inner.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ReplayService;
    use crate::net::server::NetServer;
    use crate::net::wire::Listener;
    use crate::replay::UniformReplay;

    fn exp(v: f32) -> Experience {
        Experience {
            obs: vec![v, v + 0.1, v + 0.2, v + 0.3],
            action: (v as u32) % 3,
            reward: v * 0.5,
            next_obs: vec![v + 1.0, v + 1.1, v + 1.2, v + 1.3],
            done: v as usize % 7 == 0,
        }
    }

    fn loopback_tier(seed: u64) -> (ReplayService, NetServer) {
        let svc =
            ReplayService::spawn(Box::new(UniformReplay::new(256)), 64, seed);
        let listener = Listener::bind("127.0.0.1:0").unwrap();
        let server = NetServer::spawn(svc.handle(), listener).unwrap();
        (svc, server)
    }

    #[test]
    fn remote_push_sample_update_roundtrip() {
        let (svc, server) = loopback_tier(11);
        let client =
            RemoteReplayClient::connect(server.addr(), Role::Learner).unwrap();
        for i in 0..100 {
            assert!(client.push_experience(exp(i as f32)));
        }
        let g = client.sample_gathered(32).unwrap();
        assert_eq!(g.rows(), 32);
        assert_eq!(g.obs.len(), 32 * 4);
        let (idx, td) = (g.indices.clone(), vec![0.7; 32]);
        client.recycle(g);
        assert!(client.update_priorities(idx, td));
        // second gather refills the recycled buffer through the pool
        let g2 = client.sample_gathered(32).unwrap();
        assert_eq!(g2.rows(), 32);
        client.recycle(g2);
        assert_eq!(
            client.service_stats().pushes.load(Ordering::Relaxed),
            100
        );
        assert_eq!(client.service_stats().samples.load(Ordering::Relaxed), 2);
        let pool = client.reply_pool().stats();
        assert!(
            pool.hits.load(Ordering::Relaxed) >= 1,
            "second gather should reuse the buffer"
        );
        client.close();
        // the server accounted this client's work under its id
        let clients = server.clients();
        assert_eq!(clients.len(), 1);
        assert_eq!(clients[0].id, client.client_id());
        assert_eq!(clients[0].pushes.load(Ordering::Relaxed), 100);
        assert_eq!(clients[0].samples.load(Ordering::Relaxed), 2);
        assert_eq!(clients[0].frame_errors.load(Ordering::Relaxed), 0);
        server.stop();
        svc.stop();
    }

    #[test]
    fn two_tenants_share_one_tier_with_isolated_accounting() {
        let (svc, server) = loopback_tier(12);
        let a =
            RemoteReplayClient::connect(server.addr(), Role::Actor).unwrap();
        let b =
            RemoteReplayClient::connect(server.addr(), Role::Learner).unwrap();
        assert_ne!(a.client_id(), b.client_id());
        for i in 0..40 {
            assert!(a.push_experience(exp(i as f32)));
        }
        for i in 0..20 {
            assert!(b.push_experience(exp(100.0 + i as f32)));
        }
        let g = b.sample_gathered(16).unwrap();
        assert_eq!(g.rows(), 16);
        b.recycle(g);
        a.close();
        b.close();
        let clients = server.clients();
        assert_eq!(clients.len(), 2);
        let find = |id: u32| {
            clients.iter().find(|c| c.id == id).expect("client listed")
        };
        assert_eq!(find(a.client_id()).pushes.load(Ordering::Relaxed), 40);
        assert_eq!(find(a.client_id()).samples.load(Ordering::Relaxed), 0);
        assert_eq!(find(b.client_id()).pushes.load(Ordering::Relaxed), 20);
        assert_eq!(find(b.client_id()).samples.load(Ordering::Relaxed), 1);
        server.stop();
        let mem = svc.stop();
        assert_eq!(mem.len(), 60, "both tenants' pushes landed in one tier");
    }

    #[test]
    fn snapshot_publish_relays_to_actor() {
        let (svc, server) = loopback_tier(13);
        let learner =
            RemoteReplayClient::connect(server.addr(), Role::Learner).unwrap();
        // a 4-obs / 3-action policy in the 3-layer MLP shape
        let dims = vec![4usize, 8, 8, 3];
        let params = vec![
            vec![0.1; 4 * 8],
            vec![0.0; 8],
            vec![0.2; 8 * 8],
            vec![0.0; 8],
            vec![0.3; 8 * 3],
            vec![0.0; 3],
        ];
        let slot = SnapshotSlot::new(
            PolicySnapshot::new(params.clone(), dims.clone(), 0).unwrap(),
        );
        let _relay = learner.relay_snapshots(Arc::clone(&slot));
        let actor =
            RemoteReplayClient::connect(server.addr(), Role::Actor).unwrap();
        let mirror = actor
            .wait_snapshot_slot(Duration::from_secs(5))
            .expect("initial snapshot relayed");
        assert_eq!(mirror.load().obs_dim(), 4);
        // publish a newer epoch; the actor's mirror follows
        let mut p2 = params.clone();
        p2[0][0] = 9.5;
        slot.publish(p2);
        let deadline = Instant::now() + Duration::from_secs(5);
        while mirror.epoch() < 1 {
            assert!(Instant::now() < deadline, "epoch 1 never reached mirror");
            // actor traffic carries the piggyback relay
            assert!(actor.push_experience(exp(1.0)));
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(mirror.load().params()[0][0], 9.5);
        assert_eq!(server.snapshot_epoch(), Some(1));
        learner.close();
        actor.close();
        server.stop();
        svc.stop();
    }

    #[test]
    fn reconnect_after_server_restart_resyncs() {
        let svc =
            ReplayService::spawn(Box::new(UniformReplay::new(128)), 32, 14);
        let listener = Listener::bind("127.0.0.1:0").unwrap();
        let server = NetServer::spawn(svc.handle(), listener).unwrap();
        let addr = server.addr().to_string();
        let client = RemoteReplayClient::connect_with(
            &addr,
            Role::Learner,
            ClientOptions {
                reconnect: ReconnectPolicy {
                    base: Duration::from_millis(10),
                    max: Duration::from_millis(100),
                    tries: 40,
                },
                ..ClientOptions::default()
            },
        )
        .unwrap();
        assert!(client.push_experience(exp(1.0)));
        let first_id = client.client_id();
        server.stop();
        // restart the tier on the SAME port; pushes mid-outage ride the
        // backoff loop until the new server is up
        let listener = Listener::bind(&addr).unwrap();
        let server2 = NetServer::spawn(svc.handle(), listener).unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut pushed = false;
        while Instant::now() < deadline {
            if client.push_experience(exp(2.0)) {
                pushed = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(pushed, "client never reconnected to the restarted tier");
        assert_eq!(
            client.client_id(),
            first_id,
            "fresh server restarts id assignment at the same first id"
        );
        let g = client.sample_gathered(2).unwrap();
        assert_eq!(g.rows(), 2);
        client.recycle(g);
        client.close();
        server2.stop();
        svc.stop();
    }

    #[test]
    fn malformed_frame_closes_only_that_client() {
        use std::io::Write as _;
        let (svc, server) = loopback_tier(15);
        let good =
            RemoteReplayClient::connect(server.addr(), Role::Learner).unwrap();
        for i in 0..32 {
            assert!(good.push_experience(exp(i as f32)));
        }
        // hand-roll an evil client: valid handshake, then garbage
        let mut evil = Stream::connect(server.addr()).unwrap();
        let mut buf = Vec::new();
        wire::encode_hello(&mut buf, Role::Actor);
        write_frame(&mut evil, Opcode::Hello, 0, &buf).unwrap();
        let mut payload = Vec::new();
        let ack = wire::read_frame(&mut evil, &mut payload).unwrap();
        assert_eq!(ack.opcode, Opcode::HelloAck);
        evil.write_all(&[0xFF; 64]).unwrap(); // len=0xFFFFFFFF: oversized
        // the evil connection gets dropped with a counted frame error...
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let clients = server.clients();
            let e = clients.iter().find(|c| c.id == ack.client).unwrap();
            if e.frame_errors.load(Ordering::Relaxed) == 1
                && !e.connected.load(Ordering::Relaxed)
            {
                break;
            }
            assert!(Instant::now() < deadline, "frame error never recorded");
            std::thread::sleep(Duration::from_millis(5));
        }
        // ...while the good client keeps working
        let g = good.sample_gathered(8).unwrap();
        assert_eq!(g.rows(), 8);
        good.recycle(g);
        good.close();
        server.stop();
        svc.stop();
    }
}
